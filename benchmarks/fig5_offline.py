"""Fig. 5a/5b: offline throughput and device utilization vs. load.

Paper claims to validate: BucketServe up to 3.58x UELLM and 1.31x
DistServe throughput under high load; dynamic batching lifts average
utilization to ~82%.
"""
from __future__ import annotations

from .common import SYSTEMS, emit, offline_spec, run_system

LOADS = [50, 100, 200, 400]
QUICK_LOADS = [40]


def main(quick: bool = False):
    loads = QUICK_LOADS if quick else LOADS
    rows = []
    derived = {}
    for n in loads:
        for name in SYSTEMS:
            res, nexec, wall = run_system(name, offline_spec("mixed", n))
            util = res.busy_utilization(nexec) * res.padding_efficiency()
            rows.append([
                "fig5a_offline", name, n,
                round(res.throughput_tok_s(), 1),
                round(res.output_tok_s(), 1),
                round(util, 4),
                round(res.padding_efficiency(), 4),
                res.oom_events, round(wall * 1e6, 0)])
            derived[(name, n)] = res.throughput_tok_s()
    emit(rows, ["table", "system", "n_requests", "tok_s", "out_tok_s",
                "useful_util", "pad_eff", "oom", "us_per_call"])
    hi = loads[-1]
    for base in ("uellm", "distserve"):
        ratio = derived[("bucketserve", hi)] / max(derived[(base, hi)], 1e-9)
        print(f"fig5a_ratio,bucketserve_vs_{base},{hi},{ratio:.2f},"
              f"paper={'3.58' if base == 'uellm' else '1.31'}")
    print()


if __name__ == "__main__":
    main()
