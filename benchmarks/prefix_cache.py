"""Cross-request prefix cache: cold vs shared-prefix throughput table.

Beyond-paper table (PR 3, DESIGN.md §3 "Prefix sharing"): the paged
cost model serves the SAME shared-prefix workload (N system prompts x
Zipf reuse, data/workload.py) twice — prefix cache off, then on — and
reports prompt tokens actually prefilled, hit rate, pages saved and
throughput.

CI gate: the cached run must prefill STRICTLY FEWER total prompt
tokens than the cold run (a regression here means the radix lookup or
the chunk-plan skip rotted); the harness (benchmarks/run.py) exits
nonzero on the raised AssertionError.
"""
from __future__ import annotations

import time

from repro.core.batcher import MemoryBudget
from repro.core.request import TaskType
from repro.core.scheduler import BucketServeScheduler, SchedulerConfig
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.data.workload import WorkloadSpec, generate

from .common import CFG, emit

PAGE = 128


def _run(spec: WorkloadSpec, *, prefix_cache: bool, slots: int):
    reqs = generate(spec)
    budget = MemoryBudget(hbm_bytes_per_device=A100X4.hbm_bytes,
                          n_devices=A100X4.decode_chips,
                          weight_bytes=CFG.param_count() * 2)
    sched = BucketServeScheduler(CFG, budget, SchedulerConfig(
        max_batch=slots, memory_model="paged", page_size=PAGE))
    sim = Simulator(sched, CostModel(CFG, A100X4), mode="disagg",
                    decode_slot_cap=slots, paged=True, page_size=PAGE,
                    prefix_cache=prefix_cache)
    t0 = time.perf_counter()
    res = sim.run(reqs)
    return res, time.perf_counter() - t0


def main(quick: bool = False) -> None:
    n = 48 if quick else 200
    spec = WorkloadSpec(dataset="alpaca", rps=1e6, n_requests=n,
                        max_model_len=CFG.max_seq_len,
                        task_type=TaskType.OFFLINE,
                        prefix_groups=4, prefix_tokens=1024,
                        prefix_zipf=1.2, vocab_size=CFG.vocab_size,
                        max_new_tokens=32 if quick else 0)
    rows = []
    by_mode = {}
    for cached in (False, True):
        res, wall = _run(spec, prefix_cache=cached, slots=32)
        by_mode[cached] = res
        rows.append([
            "prefix_cache", "cached" if cached else "cold", n,
            res.prefill_tokens_processed, res.prefill_tokens_skipped,
            f"{res.prefix_hit_rate():.3f}", res.prefix_pages_saved,
            res.shared_pages_peak,
            f"{res.output_tok_s():.1f}", f"{res.makespan:.2f}",
            f"{wall:.1f}"])
    emit(rows, ["table", "mode", "n", "prefill_tokens", "tokens_skipped",
                "hit_rate", "pages_saved", "shared_pages_peak",
                "out_tok_s", "makespan_s", "wall_s"])
    cold = by_mode[False]
    cached = by_mode[True]
    assert cached.prefill_tokens_processed < cold.prefill_tokens_processed, \
        (f"prefix-cache run prefilled {cached.prefill_tokens_processed} "
         f">= cold run's {cold.prefill_tokens_processed} prompt tokens — "
         "the prefix cache saved nothing")
    red = 1 - cached.prefill_tokens_processed / max(
        cold.prefill_tokens_processed, 1)
    print(f"claim,prefill_token_reduction,{red:.3f}")
    print(f"claim,throughput_ratio,"
          f"{cached.output_tok_s() / max(cold.output_tok_s(), 1e-9):.3f}")
    print()
