"""Beyond-paper: int8 KV-cache variant through the Eq.-(6) batcher.

Quantized caches double the Eq.-(6) token budget.  The gain appears in
the BUDGET-LIMITED regime (v5e 16 GiB chips, weights taking most of
HBM): the decode pool doubles and the per-iteration weight read
amortizes across 2x the tokens.  On memory-rich A100-40G at the paper's
scale the pool is not budget-limited and int8 is neutral — both rows are
shown.
"""
from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.core.baselines import SIM_MODE, hardware_for, make_scheduler
from repro.core.batcher import MemoryBudget
from repro.core.simulator import A100X4, CostModel, HardwareSpec, Simulator

from .common import emit, offline_spec
from repro.data.workload import generate

V5E_4 = HardwareSpec("v5e-4", 197e12, 819e9, 50e9, 16 * 2 ** 30,
                     prefill_chips=2, decode_chips=2)


def main(quick: bool = False):
    rows = []
    n = 60 if quick else 300
    for hw_name, base_hw in (("v5e-4(16GiB)", V5E_4),
                             ("a100x4(40GiB)", A100X4)):
        for variant in ("", "int8"):
            cfg = get_config("llama2-13b", variant=variant)
            hw, nd, nexec = hardware_for("bucketserve", base_hw)
            budget = MemoryBudget(hw.hbm_bytes, nd, cfg.param_count() * 2)
            sched = make_scheduler("bucketserve", cfg, budget)
            sim = Simulator(sched, CostModel(cfg, hw),
                            mode=SIM_MODE["bucketserve"])
            res = sim.run(generate(offline_spec("mixed", n)),
                          time_limit=7200)
            rows.append(["kv_quant", hw_name, variant or "bf16",
                         int(sched.batcher.token_budget()),
                         round(res.output_tok_s(), 0),
                         round(res.throughput_tok_s(), 0),
                         res.oom_events])
    emit(rows, ["table", "hardware", "cache", "eq6_token_budget",
                "out_tok_s", "tok_s", "oom"])


if __name__ == "__main__":
    main()
