"""Beyond-paper: int8 KV-cache variant through the Eq.-(6) batcher.

Quantized caches double the Eq.-(6) token budget.  The gain appears in
the BUDGET-LIMITED regime (v5e 16 GiB chips, weights taking most of
HBM): the decode pool doubles and the per-iteration weight read
amortizes across 2x the tokens.  On memory-rich A100-40G at the paper's
scale the pool is not budget-limited and int8 is neutral — both rows are
shown.

Two memory models per (hardware, cache dtype) cell, both on the unified
ServingLoop/CostModelBackend path:

* ``sum``   — Eq. (6) on the HBM-derived token budget (the classic row:
  int8 doubles ``eq6_token_budget``);
* ``paged`` — a FIXED ``kv_pool_tokens`` byte budget pushed through
  ``paging.device_pool_pages``: the ``pool_pages`` column shows the
  int8 pool genuinely holding ~2x the pages of the bf16 pool under the
  SAME bytes (byte-denominated accounting, DESIGN.md §3 "Tier
  precision") — asserted as a CI gate, not just printed.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.batcher import MemoryBudget
from repro.core.scheduler import BucketServeScheduler, SchedulerConfig
from repro.core.serving_loop import LoopConfig, ServingLoop
from repro.core.simulator import (A100X4, CostModel, CostModelBackend,
                                  HardwareSpec)
from repro.data.workload import generate

from .common import emit, offline_spec

V5E_4 = HardwareSpec("v5e-4", 197e12, 819e9, 50e9, 16 * 2 ** 30,
                     prefill_chips=2, decode_chips=2)

PAGE = 128
POOL_TOKENS = 512 * PAGE          # fixed bf16-reference byte budget


def _run(cfg, hw, *, paged: bool, n: int):
    budget = MemoryBudget(hbm_bytes_per_device=hw.hbm_bytes,
                          n_devices=hw.decode_chips,
                          weight_bytes=cfg.param_count() * 2)
    sched = BucketServeScheduler(cfg, budget, SchedulerConfig(
        memory_model="paged" if paged else "sum", page_size=PAGE))
    cost = CostModel(cfg, hw)
    backend = CostModelBackend(
        cost, kv_budget=cost.kv_budget_tokens(hw.decode_chips),
        paged=paged, page_size=PAGE,
        kv_pool_tokens=POOL_TOKENS if paged else None)
    loop = ServingLoop(sched, backend, LoopConfig(mode="disagg"))
    res = loop.run(generate(offline_spec("mixed", n)), time_limit=7200)
    return res, sched, backend


def main(quick: bool = False):
    rows = []
    n = 60 if quick else 300
    pool_pages = {}
    for hw_name, hw in (("v5e-4(16GiB)", V5E_4), ("a100x4(40GiB)", A100X4)):
        for variant in ("", "int8"):
            cfg = get_config("llama2-13b", variant=variant)
            for paged in (False, True):
                res, sched, backend = _run(cfg, hw, paged=paged, n=n)
                pages = backend.alloc.n_pages if paged else "-"
                if paged:
                    pool_pages[(hw_name, variant)] = backend.alloc.n_pages
                rows.append(["kv_quant", hw_name, variant or "bf16",
                             "paged" if paged else "sum",
                             int(sched.batcher.token_budget()), pages,
                             round(res.output_tok_s(), 0),
                             round(res.throughput_tok_s(), 0),
                             res.oom_events])
    emit(rows, ["table", "hardware", "cache", "mem_model",
                "eq6_token_budget", "pool_pages", "out_tok_s", "tok_s",
                "oom"])
    # CI gate: the SAME kv_pool_tokens byte budget buys ~2x the pages
    # at int8 cache precision (byte-denominated pool sizing)
    for hw_name in ("v5e-4(16GiB)", "a100x4(40GiB)"):
        bf16 = pool_pages[(hw_name, "")]
        int8 = pool_pages[(hw_name, "int8")]
        assert int8 >= 1.8 * bf16, \
            (f"{hw_name}: int8 pool holds {int8} pages vs bf16's {bf16} "
             "under the same byte budget — pool sizing is not "
             "byte-denominated")


if __name__ == "__main__":
    main()
