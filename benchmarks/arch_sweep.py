"""Beyond-paper: BucketServe across the full architecture zoo.

The paper evaluates Llama2-13B only; here the same scheduler serves all
10 assigned architectures on a v5e-8 slice cost model.  This exercises
the generalized Eq.-(6) memory model (KV for dense/MoE, O(1) state for
SSM, window-capped for hybrid/SWA) — the table shows how the memory
model changes both sustainable concurrency and the bucketing gain.
"""
from __future__ import annotations

import dataclasses

from repro.configs import ASSIGNED, get_config
from repro.core.baselines import SIM_MODE, make_scheduler
from repro.core.batcher import MemoryBudget
from repro.core.request import TaskType
from repro.core.simulator import CostModel, HardwareSpec, Simulator
from repro.data.workload import WorkloadSpec, generate

from .common import emit

V5E_8 = HardwareSpec("v5e-8", 197e12, 819e9, 50e9, 16 * 2 ** 30,
                     prefill_chips=4, decode_chips=4)


def main(quick: bool = False):
    rows = []
    archs = ASSIGNED[:3] if quick else ASSIGNED
    for arch in archs:
        cfg = get_config(arch)
        if not cfg.has_decode:
            rows.append(["arch_sweep", arch, cfg.arch_type, "SKIP",
                         "encoder-only", "", "", ""])
            continue
        cfg = dataclasses.replace(cfg, max_seq_len=min(cfg.max_seq_len,
                                                       8192))
        weight_bytes = cfg.param_count() * 2
        if weight_bytes > 0.9 * V5E_8.hbm_bytes * 8:
            rows.append(["arch_sweep", arch, cfg.arch_type, "SKIP",
                         "weights exceed v5e-8", "", "", ""])
            continue
        spec = WorkloadSpec(dataset="mixed", rps=1e6,
                            n_requests=60 if quick else 150,
                            max_model_len=cfg.max_seq_len,
                            task_type=TaskType.OFFLINE)
        out = {}
        for name in ("bucketserve", "distserve"):
            nd = 4
            budget = MemoryBudget(V5E_8.hbm_bytes, nd, weight_bytes)
            sim = Simulator(make_scheduler(name, cfg, budget),
                            CostModel(cfg, V5E_8), mode=SIM_MODE[name])
            out[name] = sim.run(generate(spec), time_limit=7200)
        b, d = out["bucketserve"], out["distserve"]
        kv_tok = cfg.kv_bytes_per_token()
        rows.append([
            "arch_sweep", arch, cfg.arch_type,
            f"{kv_tok/1024:.0f}KiB/tok" if kv_tok else "state-only",
            round(b.throughput_tok_s(), 0),
            round(d.throughput_tok_s(), 0),
            round(b.throughput_tok_s() / max(d.throughput_tok_s(), 1e-9), 2),
            round(b.padding_efficiency(), 2)])
    emit(rows, ["table", "arch", "family", "kv_cost", "bucketserve_tok_s",
                "distserve_tok_s", "speedup", "bucket_pad_eff"])


if __name__ == "__main__":
    main()
