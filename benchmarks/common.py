"""Shared benchmark plumbing (paper §V setup: Llama2-13B on 4xA100-40G)."""
from __future__ import annotations

import dataclasses
import time

from repro.configs import get_config
from repro.core.baselines import SIM_MODE, hardware_for, make_scheduler
from repro.core.batcher import MemoryBudget
from repro.core.request import TaskType
from repro.core.simulator import A100X4, CostModel, SimResult, Simulator
from repro.data.workload import WorkloadSpec, generate

CFG = get_config("llama2-13b")
SYSTEMS = ["bucketserve", "distserve", "uellm", "orca", "static"]
PAPER_SYSTEMS = ["bucketserve", "distserve", "uellm"]


def run_system(name: str, spec: WorkloadSpec, *, seed: int = 0,
               time_limit: float = 3600.0, **sched_kw):
    spec = dataclasses.replace(spec, seed=seed)
    reqs = generate(spec)
    hw, nd, nexec = hardware_for(name, A100X4)
    budget = MemoryBudget(hbm_bytes_per_device=hw.hbm_bytes, n_devices=nd,
                          weight_bytes=CFG.param_count() * 2)
    sched = make_scheduler(name, CFG, budget, **sched_kw)
    sim = Simulator(sched, CostModel(CFG, hw), mode=SIM_MODE[name])
    t0 = time.perf_counter()
    res = sim.run(reqs, time_limit=time_limit)
    wall = time.perf_counter() - t0
    return res, nexec, wall


def offline_spec(dataset: str, n: int) -> WorkloadSpec:
    """Offline: the full request set is queued up-front (paper Fig. 5a)."""
    return WorkloadSpec(dataset=dataset, rps=1e6, n_requests=n,
                        max_model_len=CFG.max_seq_len,
                        task_type=TaskType.OFFLINE)


def online_spec(dataset: str, rps: float, n: int = 200) -> WorkloadSpec:
    return WorkloadSpec(dataset=dataset, rps=rps, n_requests=n,
                        max_model_len=CFG.max_seq_len,
                        task_type=TaskType.ONLINE)


# ---- machine-readable artifact capture (PR 8) -------------------------
# ``emit`` records every CSV block it prints so benchmarks/run.py can
# persist a BENCH_<table>.json artifact per table — the bench
# trajectory is otherwise write-only stdout.
_captured = []


def reset_capture() -> None:
    _captured.clear()


def captured():
    return list(_captured)


def _json_cell(x):
    return x if isinstance(x, (bool, int, float, str)) or x is None \
        else str(x)


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    print()
    _captured.append({"header": [str(h) for h in header],
                      "rows": [[_json_cell(x) for x in r] for r in rows]})
